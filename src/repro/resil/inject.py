"""Deterministic fault injection: ``FaultPlan`` — glob rules → faults.

Training on edge hardware with fixed-point log-domain arithmetic is
exactly the regime where bit flips, Δ-LUT corruption, and device dropouts
are real events, not tail risk.  This module makes them *first-class,
reproducible inputs*: a :class:`FaultPlan` is a seed-keyed, serializable
description of which hardware-realistic faults hit which layers at which
steps, mirroring :class:`~repro.core.plan.NumericsPlan` (same glob-rule
shape, same lossless ``parse``/``str`` round-trip, same
``validate_paths`` typo guard).

Serialized form::

    seed=42,start=3,stop=4;hidden=flip_w:0.001,sat_lanes:2;serve=hang_step:3
    └─ head: plan keys ──┘ └─ rule 1 ─────────────────────┘└─ rule 2 ────┘

* segments are ``;``-separated; the first (always present — ``seed`` is
  always serialized) is ``key=value`` plan keys: ``seed`` (PRNG root of
  every stochastic fault), ``start``/``stop`` (the half-open step window
  ``[start, stop)`` in which in-graph faults fire; ``stop=-1`` = no end);
* each rule is ``<pattern>=<kind>:<value>[,...]`` with patterns fnmatch
  globs over layer paths (plus the pseudo-path ``serve`` for engine-level
  faults) and kinds from :data:`FAULT_KINDS`.

The injection contract mirrors the telemetry contract (obs/metrics.py):

* **No plan ⇒ no op.**  Every injection helper returns its input
  *object* unchanged when no plan is active or no rule matches, so the
  traced graph is *identical* to a fault-free build — faults are
  injected, never accidental (``tests/test_resil.py`` pins this the same
  way ``tests/test_obs.py`` pins the telemetry no-op).
* **Deterministic.**  Every stochastic choice derives from
  ``fold_in(PRNGKey(plan.seed), crc32(site))`` (+ the traced step for
  per-step faults): same plan ⇒ same faults, on the emulate and pallas
  lanes alike — the injection sites sit *between* ops, on the LNS code
  tensors both lanes share.
* **Ambient activation.**  Like the obs collector stack, a plan activates
  via ``with injecting(plan, step):`` around the step trace; library code
  consults :func:`active_plan` and never threads plans through
  signatures.  ``suspended()`` masks the plan across regions whose
  traces must stay clean (shard_map bodies — the same tracer-leak
  discipline obs uses).

This module deliberately imports nothing from ``repro.core``:
``paper/mlp.py`` imports it, and the fault surface is duck-typed — an
LNS format is anything with ``qi``/``qf``/``code_max``/``zero_code``, an
LNS tensor anything with ``.code``/``.sign`` reconstructible via
``type(a)(code, sign)``.
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import fnmatch
import functools
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: Characters that would collide with the plan/rule/value separators.
_PATTERN_FORBIDDEN = set(";=,:")


def _parse_rate(kind, v):
    r = float(v)
    if not (0.0 < r <= 1.0):
        raise ValueError(f"fault {kind}:{v} — rate must be in (0, 1]")
    return r


def _parse_count(kind, v, lo=1):
    n = int(v)
    if n < lo:
        raise ValueError(f"fault {kind}:{v} — expected an integer >= {lo}")
    return n


#: kind → (parse+validate, canonical-serialize).  The closed vocabulary of
#: injectable faults; extend only by appending (drill baselines key on it).
FAULT_KINDS = {
    # in-graph, per-step (keyed by plan.seed × site × step):
    "flip_w":    (lambda v: _parse_rate("flip_w", v), repr),      # weight-code bit-flip rate
    "flip_act":  (lambda v: _parse_rate("flip_act", v), repr),    # activation-code bit-flip rate
    "sat_lanes": (lambda v: _parse_count("sat_lanes", v), str),   # stuck-at-code_max output lanes
    # host-static (applied when the model is built):
    "lut":       (lambda v: _parse_count("lut", v), str),         # corrupted Δ-LUT entries per table
    # DP segment-partial faults (deterministic, no randomness):
    "drop_seg":  (lambda v: _parse_count("drop_seg", v, 0), str),  # global segment index zeroed
    "dup_seg":   (lambda v: _parse_count("dup_seg", v, 0), str),   # global segment index cloned into +1
    # serve-engine faults (host-side, pattern 'serve'):
    "hang_step": (lambda v: _parse_count("hang_step", v, 0), str),  # engine step that "hangs"
    "slow_req":  (lambda v: _parse_count("slow_req", v), str),      # rid % v == 0 decodes at half speed
}


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One ``pattern=kind:value,...`` rule of a :class:`FaultPlan`.

    ``faults`` holds canonicalized ``(kind, value-string)`` pairs sorted
    by kind, so equal-meaning rules compare/hash equal and the plan's
    ``str`` round-trips losslessly.
    """

    pattern: str
    faults: Tuple[Tuple[str, str], ...]

    def __post_init__(self):
        if not self.pattern:
            raise ValueError("empty layer pattern in fault plan rule")
        bad = _PATTERN_FORBIDDEN & set(self.pattern)
        if bad:
            raise ValueError(
                f"fault pattern {self.pattern!r} contains reserved "
                f"character(s) {''.join(sorted(bad))!r}; patterns are "
                f"fnmatch globs over layer paths (e.g. 'hidden', "
                f"'layers.*') or the pseudo-path 'serve'")
        if not self.faults:
            raise ValueError(
                f"rule {self.pattern!r} has no faults; expected "
                f"'{self.pattern}=kind:value[,kind:value...]'")
        kinds = [k for k, _ in self.faults]
        if len(kinds) != len(set(kinds)):
            dup = sorted(k for k in set(kinds) if kinds.count(k) > 1)
            raise ValueError(
                f"rule {self.pattern!r} sets {', '.join(dup)} more than "
                f"once")
        for k, _ in self.faults:
            if k not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {k!r} in rule {self.pattern!r}; "
                    f"valid kinds: {', '.join(sorted(FAULT_KINDS))}")

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)

    def __str__(self) -> str:
        return self.pattern + "=" + ",".join(
            f"{k}:{v}" for k, v in self.faults)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed-keyed schedule of injected faults over layer-path globs.

    Frozen/hashable (jit-static, like :class:`NumericsPlan` — the plan
    rides on the model object, so each plan gets its own trace).  Rules
    apply in declaration order; a later matching rule overrides an
    earlier one kind-by-kind.
    """

    seed: int = 0
    start: int = 0         # first step in-graph faults fire (inclusive)
    stop: int = -1         # first step they stop (-1 = never)
    rules: Tuple[FaultRule, ...] = ()

    def __post_init__(self):
        if self.start < 0:
            raise ValueError(f"start must be >= 0, got {self.start}")
        if self.stop != -1 and self.stop <= self.start:
            raise ValueError(
                f"stop={self.stop} must be -1 (open) or > start="
                f"{self.start}")

    # -- parse / serialize ------------------------------------------------
    @staticmethod
    def parse(text: "str | FaultPlan | None") -> "Optional[FaultPlan]":
        """Parse a fault-plan string (``None``/``''`` pass through as
        ``None`` — no plan, true no-op)."""
        if text is None or isinstance(text, FaultPlan):
            return text
        text = str(text).strip()
        if not text:
            return None
        return _parse_fault_plan_cached(text)

    def __str__(self) -> str:
        head = [f"seed={self.seed}"]
        if self.start:
            head.append(f"start={self.start}")
        if self.stop != -1:
            head.append(f"stop={self.stop}")
        return ";".join([",".join(head)] + [str(r) for r in self.rules])

    # -- resolution -------------------------------------------------------
    def resolve(self, path: str) -> dict:
        """``{kind: typed value}`` hitting layer ``path`` (later rules
        override earlier ones per kind — the NumericsPlan precedence
        contract)."""
        return _resolve_faults_cached(self, path)

    def validate_paths(self, paths) -> "FaultPlan":
        """Raise if any rule pattern matches none of ``paths`` — a typo'd
        pattern must not silently inject nothing."""
        paths = tuple(paths)
        dead = [str(r) for r in self.rules
                if not any(r.matches(p) for p in paths)]
        if dead:
            raise ValueError(
                f"fault plan rule(s) {dead} match no layer path; "
                f"known layer paths: {', '.join(paths)}")
        return self


@functools.lru_cache(maxsize=None)
def _parse_fault_plan_cached(text: str) -> FaultPlan:
    segments = [s.strip() for s in text.split(";")]
    head, keys = segments[0], {}
    for tok in head.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if "=" not in tok or ":" in tok:
            raise ValueError(
                f"fault plan head token {tok!r}; the first segment is "
                f"'seed=N[,start=A][,stop=B]' (rules come after the "
                f"first ';')")
        k, v = (p.strip() for p in tok.split("=", 1))
        if k not in ("seed", "start", "stop"):
            raise ValueError(
                f"unknown fault plan key {k!r}; valid keys: seed, "
                f"start, stop")
        if k in keys:
            raise ValueError(f"fault plan sets {k} more than once")
        keys[k] = int(v)
    rules = []
    for seg in segments[1:]:
        if not seg:
            continue
        if "=" not in seg:
            raise ValueError(
                f"fault rule {seg!r} has no '='; expected "
                f"'<pattern>=<kind>:<value>[,<kind>:<value>...]'")
        pattern, body = (p.strip() for p in seg.split("=", 1))
        kv = []
        for tok in body.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if ":" not in tok:
                raise ValueError(
                    f"fault {tok!r} in rule {pattern!r} has no ':'; "
                    f"expected '<kind>:<value>'")
            kv.append(tuple(p.strip() for p in tok.split(":", 1)))
        rules.append(_canonical_fault_rule(pattern, kv))
    return FaultPlan(rules=tuple(rules), **keys)


def _canonical_fault_rule(pattern: str, kv) -> FaultRule:
    """Validate values through :data:`FAULT_KINDS` and re-serialize them
    canonically (``flip_w:1e-3`` stores as ``0.001``) so ``parse``/``str``
    round-trips losslessly and rule equality is semantic."""
    out = []
    for kind, v in kv:
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in rule {pattern!r}; "
                f"valid kinds: {', '.join(sorted(FAULT_KINDS))}")
        parse, serialize = FAULT_KINDS[kind]
        out.append((kind, serialize(parse(v))))
    return FaultRule(pattern=pattern,
                     faults=tuple(sorted(out)))


@functools.lru_cache(maxsize=None)
def _resolve_faults_cached(plan: FaultPlan, path: str) -> dict:
    faults = {}
    for rule in plan.rules:
        if rule.matches(path):
            for kind, v in rule.faults:
                faults[kind] = FAULT_KINDS[kind][0](v)
    return faults


def fault_plan(pattern_faults: dict = None, *, seed: int = 0,
               start: int = 0, stop: int = -1) -> FaultPlan:
    """Convenience constructor: ``fault_plan({"hidden": "flip_w:0.01"})``."""
    rules = []
    for pattern, body in (pattern_faults or {}).items():
        kv = [tuple(p.strip() for p in tok.split(":", 1))
              for tok in body.split(",") if tok.strip()]
        rules.append(_canonical_fault_rule(pattern, kv))
    return FaultPlan(seed=seed, start=start, stop=stop, rules=tuple(rules))


# -- ambient activation (the obs collector-stack pattern) -----------------
_ACTIVE: list = []   # (plan | None, step | None) — top of stack wins


@contextlib.contextmanager
def injecting(plan: Optional[FaultPlan], step=None):
    """Activate ``plan`` (with traced ``step`` for windowed faults) for
    the enclosed trace.  ``injecting(None)`` is a true no-op: every
    helper sees no plan, so the graph is the fault-free graph."""
    _ACTIVE.append((plan, step))
    try:
        yield
    finally:
        _ACTIVE.pop()


@contextlib.contextmanager
def suspended():
    """Mask any active plan (shard_map bodies: the outer step tracer must
    not leak into the per-device trace — same discipline as obs)."""
    _ACTIVE.append((None, None))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE[-1][0] if _ACTIVE else None


def active_step():
    return _ACTIVE[-1][1] if _ACTIVE else None


# -- keying + windowing ---------------------------------------------------
def _site_key(plan: FaultPlan, site: str, step=None):
    """Per-site PRNG key: root seed × crc32(site) × (traced) step."""
    key = jax.random.fold_in(jax.random.PRNGKey(plan.seed),
                             zlib.crc32(site.encode()) & 0x7FFFFFFF)
    if step is not None:
        key = jax.random.fold_in(key, step)
    return key


def _window(plan: FaultPlan, step):
    """Traced bool: is ``step`` inside ``[start, stop)``?  ``None`` (no
    step pushed) means the window is statically open."""
    if step is None:
        return None
    m = step >= plan.start
    if plan.stop != -1:
        m = m & (step < plan.stop)
    return m


def _masked(hit, window):
    return hit if window is None else hit & window


# -- in-graph injection helpers -------------------------------------------
def _flip_bits(code, rate: float, nbits: int, key, window):
    """Flip one uniformly-chosen low bit of ``code`` per hit element.

    Codes live in ``[code_min, code_max]`` = ``[-(2^n), 2^n - 1]`` with
    ``n = qi + qf``; XOR-ing any bit ``b < n`` of the two's-complement
    representation keeps the result in range (a flip *can* land on the
    ``zero_code`` sentinel — that is a flush-to-zero, which is exactly
    what such a flip does in hardware).
    """
    kh, kb = jax.random.split(key)
    hit = _masked(jax.random.uniform(kh, code.shape) < rate, window)
    bit = jax.random.randint(kb, code.shape, 0, nbits)
    return jnp.where(hit, code ^ (jnp.int32(1) << bit), code)


def inject_codes(a, fmt, *, layer: str, site: str = "act"):
    """Inject activation-plane faults (``flip_act``, ``sat_lanes``) into
    the LNS tensor ``a``; returns ``a`` itself when nothing applies."""
    plan, step = active_plan(), active_step()
    if plan is None:
        return a
    faults = plan.resolve(layer)
    rate, lanes = faults.get("flip_act"), faults.get("sat_lanes")
    if rate is None and lanes is None:
        return a
    window = _window(plan, step)
    code, sign = a.code, a.sign
    if rate is not None:
        key = _site_key(plan, f"{layer}/{site}/flip_act", step)
        code = _flip_bits(code, rate, fmt.qi + fmt.qf, key, window)
    if lanes is not None:
        # Stuck-at-saturation output lanes: a host-static choice of
        # last-axis lanes (a broken MAC column, not transient noise) pins
        # to +code_max inside the step window.
        ncols = code.shape[-1]
        rng = np.random.default_rng(
            plan.seed ^ zlib.crc32(f"{layer}/{site}/sat_lanes".encode()))
        pick = np.zeros((ncols,), bool)
        pick[rng.permutation(ncols)[:min(lanes, ncols)]] = True
        mask = _masked(jnp.asarray(pick), window)
        code = jnp.where(mask, jnp.int32(fmt.code_max), code)
        sign = jnp.where(mask, jnp.zeros_like(sign), sign)
    return type(a)(code, sign)


def inject_param_codes(params: dict, *, param_fmts: dict,
                       param_layer: dict):
    """Inject ``flip_w`` weight-code bit flips into a parameter pytree;
    returns the *same dict object* when no parameter is hit (no-op
    graph contract)."""
    plan, step = active_plan(), active_step()
    if plan is None:
        return params
    out, changed = {}, False
    window = _window(plan, step)
    for k, w in params.items():
        rate = plan.resolve(param_layer[k]).get("flip_w")
        if rate is None:
            out[k] = w
            continue
        fmt = param_fmts[k]
        key = _site_key(plan, f"{param_layer[k]}/w.{k}/flip_w", step)
        code = _flip_bits(w.code, rate, fmt.qi + fmt.qf, key, window)
        out[k] = type(w)(code, w.sign)
        changed = True
    return out if changed else params


def inject_segment_partials(grads: dict, *, param_fmts: dict,
                            param_layer: dict, segs_local: int,
                            axis_name: str = None, plan: FaultPlan = None):
    """Inject DP segment-partial faults (``drop_seg`` / ``dup_seg``).

    Operates on per-segment gradient partials with a leading local
    segment axis (``segs_local`` slots).  ``drop_seg:s`` zeroes global
    segment ``s``'s partial (a lost device / dropped all-gather message);
    ``dup_seg:s`` overwrites slot ``s+1`` with a copy of slot ``s`` (a
    duplicated message) — co-located slots only, mirroring how a
    retransmit bug manifests.  Inside ``shard_map`` the global slot index
    is recovered from ``lax.axis_index``; pass ``plan`` explicitly there
    (the ambient stack is suspended across mapped bodies).  Segment
    faults are not step-windowed: they model a persistent transport
    fault, active for as long as the plan is.

    Returns the same dict object when no segment fault is configured.
    """
    if plan is None:
        plan = active_plan()
    if plan is None:
        return grads
    out, changed = {}, False
    for k, g in grads.items():
        faults = plan.resolve(param_layer[k])
        drop, dup = faults.get("drop_seg"), faults.get("dup_seg")
        if drop is None and dup is None:
            out[k] = g
            continue
        fmt = param_fmts[k]
        if axis_name is None:
            base = 0
        else:
            base = jax.lax.axis_index(axis_name) * segs_local
        slot = jnp.arange(segs_local)
        code, sign = g.code, g.sign
        shape1 = (segs_local,) + (1,) * (code.ndim - 1)
        if drop is not None:
            m = (slot + base == drop).reshape(shape1)
            code = jnp.where(m, jnp.int32(fmt.zero_code), code)
            sign = jnp.where(m, jnp.zeros_like(sign), sign)
        if dup is not None:
            # slot s+1 := slot s, when both live on this shard
            src = jnp.roll(code, 1, axis=0)
            src_s = jnp.roll(sign, 1, axis=0)
            m = (slot + base == dup + 1).reshape(shape1) \
                & (slot > 0).reshape(shape1)
            code = jnp.where(m, src, code)
            sign = jnp.where(m, src_s, sign)
        out[k] = type(g)(code, sign)
        changed = True
    return out if changed else grads


# -- host-side (build-time) injection -------------------------------------
def corrupt_engine(eng, plan: Optional[FaultPlan], layer: str):
    """Return a copy of Δ-engine ``eng`` with ``lut`` faults applied: n
    entries of each LUT get one low bit flipped (clipped back to the
    table's live value range so the arithmetic stays in-format — the
    entry is *wrong*, not out-of-domain).  Returns ``eng`` itself when no
    ``lut`` fault targets ``layer``, or when the engine has no tables
    (``exact``/``bitshift`` kinds evaluate Δ in-graph; only ``lut``
    engines model corruptible ROM).
    """
    if plan is None:
        return eng
    n = plan.resolve(layer).get("lut")
    if not n or getattr(eng, "spec", None) is None \
            or eng.spec.kind != "lut":
        return eng
    new = copy.copy(eng)   # engines are cached/shared: never mutate
    rng = np.random.default_rng(
        plan.seed ^ zlib.crc32(f"{layer}/lut".encode()))
    for name in ("_tab_plus", "_tab_minus"):
        tab = np.array(getattr(eng, name))
        lo = 1 if name == "_tab_minus" else 0  # keep the flush sentinel
        live = tab[lo:]
        if live.size == 0:
            continue
        k = min(n, live.size)
        idx = lo + rng.permutation(live.size)[:k]
        bits = rng.integers(0, 3, size=k)
        tab[idx] = np.clip(tab[idx] ^ (1 << bits).astype(np.int32),
                           int(live.min()), int(live.max()))
        setattr(new, name, tab)
    return new


# -- serve-side fault queries (host Python, not in-graph) -----------------
def serve_faults(plan: Optional[FaultPlan]) -> dict:
    """The faults targeting the serve engine (pseudo-path ``'serve'``)."""
    if plan is None:
        return {}
    return plan.resolve("serve")
