"""Guardrails: detectors over the obs numerics taps wired to recovery.

PR 7's telemetry gave the training loop sensors (saturation counters,
quantize-flush counters, the loss readout); this module makes something
*act* on them.  Three detectors — saturation storm, zero-flush spike,
nonfinite/spiking loss — feed three recovery policies:

* **Step rollback** from a bounded in-memory :class:`SnapshotRing` of
  host-side state copies (weight codes + ⊞-momentum + rng), the cheap
  undo for transient faults (a bit-flip storm inside one step window).
* **Format widening**: a persistent saturation storm in a narrow layer
  becomes a :class:`~repro.core.plan.NumericsPlan` override
  (``plan.with_rule(layer, fmt=<wider>)``) — the model is rebuilt under
  the widened plan and the layer's codes are converted with the exact
  integer barrel shifts of :func:`~repro.core.lns.convert_format`, so
  widening itself never loses information.  The override is logged
  through obs (``guard.widened`` counter + the event log carries both
  plan strings).
* **DP device-drop recovery** (:func:`recover_segment_partials`): the
  canonical device-count-independent segmentation (``lns_reduce``) makes
  each segment partial a pure function of its own batch rows, so a lost
  device's segments can be *recomputed* and spliced into the surviving
  partial stack; the fixed-schedule ⊞ combine then yields weight codes
  **bit-identical** to a fresh run at the surviving device count — the
  contract ``tests/test_resil.py`` pins.

Everything here is host-side policy around the jitted step: the step
functions themselves stay pure and the guardrails never fork the traced
arithmetic (disabled guardrails ⇒ the exact train_step graphs of HEAD).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import convert_format
from ..core.plan import NumericsPlan
from ..obs.registry import MetricsRegistry
from . import inject as _inj


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Detector thresholds + recovery policy switches.

    The all-off config (``GuardConfig(rollback=False, widen=False)``)
    reduces :class:`GuardedTrainer` to a plain metrics loop — same
    trained codes as driving ``train_step_metrics`` by hand.
    """

    sat_frac: float = 0.25      # saturations / elems per layer → storm
    flush_frac: float = 0.60    # zero-flushes (or q_flush) / elems → spike
    loss_abs: float = 1.0e4     # absolute loss ceiling
    loss_spike: float = 10.0    # × median of recent losses
    ring: int = 4               # snapshots kept (bounded memory)
    snapshot_every: int = 1     # push cadence in steps
    rollback: bool = True
    widen: bool = True
    widen_fmt: str = "lns16"    # target format of the widening override
    cooldown: int = 2           # steps to hold fire after a recovery


@dataclasses.dataclass(frozen=True)
class Alert:
    kind: str            # 'saturation-storm' | 'zero-flush-spike' |
                         # 'nonfinite-loss' | 'loss-spike'
    layer: Optional[str]  # None for loss alerts (not layer-attributable)
    value: float         # the offending fraction / loss value
    step: int


class SnapshotRing:
    """Bounded ring of host-side training-state snapshots.

    Entries are ``jax.device_get`` copies (LNSArray pytrees with numpy
    leaves), so a rollback is immune to any later in-place device-side
    donation and costs no device memory.  ``rng`` rides along for steps
    that thread one (the paper MLP step is rng-free; the slot keeps the
    snapshot format stable for steps that are not).
    """

    def __init__(self, capacity: int):
        self._ring = collections.deque(maxlen=max(1, capacity))

    def push(self, step: int, params, momentum=None, rng=None):
        self._ring.append(
            (step, jax.device_get((params, momentum, rng))))

    def latest(self):
        """``(step, (params, momentum, rng))`` of the newest snapshot, or
        ``None`` when empty."""
        return self._ring[-1] if self._ring else None

    def __len__(self):
        return len(self._ring)


def detect(taps: dict, loss: float, cfg: GuardConfig,
           recent_losses=(), step: int = 0) -> List[Alert]:
    """Run the three detectors over one step's taps + loss readout.

    ``taps`` is the ``"layer/op/counter"`` dict a ``*_metrics`` entry
    point returns.  Saturation and flush fractions are computed per
    (layer, op) pair against that pair's own ``elems``/``q_elems``
    denominator, and the *worst* offending pair per layer raises the
    alert — detectors read the raw taps, so they see exactly what the
    arithmetic saw (including injected faults: detection latency in the
    drills is measured in steps from injection to the first alert).
    """
    alerts: List[Alert] = []
    worst_sat: dict = {}
    worst_flush: dict = {}
    for label, v in taps.items():
        parts = label.split("/")
        if len(parts) != 3:
            continue
        layer, op, counter = parts
        v = np.asarray(v)
        if v.ndim != 0:
            continue  # dhist buckets etc.
        v = int(v)
        if counter == "sat":
            denom = int(np.asarray(taps.get(f"{layer}/{op}/elems", 0)))
            if denom:
                frac = v / denom
                if frac > worst_sat.get(layer, 0.0):
                    worst_sat[layer] = frac
        elif counter in ("zero", "q_flush"):
            dkey = f"{layer}/{op}/" + (
                "elems" if counter == "zero" else "q_elems")
            denom = int(np.asarray(taps.get(dkey, 0)))
            if denom:
                frac = v / denom
                if frac > worst_flush.get(layer, 0.0):
                    worst_flush[layer] = frac
    for layer in sorted(worst_sat):
        if worst_sat[layer] >= cfg.sat_frac:
            alerts.append(Alert("saturation-storm", layer,
                                worst_sat[layer], step))
    for layer in sorted(worst_flush):
        if worst_flush[layer] >= cfg.flush_frac:
            alerts.append(Alert("zero-flush-spike", layer,
                                worst_flush[layer], step))
    loss = float(loss)
    if not math.isfinite(loss):
        alerts.append(Alert("nonfinite-loss", None, loss, step))
    else:
        if loss > cfg.loss_abs:
            alerts.append(Alert("loss-spike", None, loss, step))
        elif recent_losses:
            med = float(np.median(np.asarray(recent_losses)))
            if med > 0 and loss > cfg.loss_spike * med:
                alerts.append(Alert("loss-spike", None, loss, step))
    return alerts


def _inner(model):
    """The per-layer LNSMLP view of a (possibly DP-wrapped) model."""
    return getattr(model, "inner", model)


class GuardedTrainer:
    """Host-side training loop wrapper: snapshot → step → detect → act.

    Drives the model's metrics entry point (``train_step_faults_metrics``
    when the model carries a :class:`~repro.resil.inject.FaultPlan`,
    ``train_step_metrics`` otherwise), feeds the taps + loss readout to
    :func:`detect`, and applies the configured recovery:

    * loss alerts (nonfinite / spike) → **rollback** to the most recent
      snapshot (which is this step's *pre*-state at
      ``snapshot_every=1`` — the damaged update is discarded);
    * layer alerts (saturation storm / flush spike) → **widen** the layer
      via a plan override (plus a rollback when enabled, so the widened
      format resumes from undamaged codes).

    A ``cooldown`` holds recovery off for a few steps afterwards so a
    fault window longer than one step cannot thrash the ring.  Every
    recovery is appended to :attr:`events` and counted in the registry
    (``guard.alerts`` / ``guard.rollbacks`` / ``guard.widened``).
    """

    def __init__(self, model, params, momentum=None, *,
                 guard: GuardConfig = GuardConfig(),
                 registry: Optional[MetricsRegistry] = None):
        self.model = model
        self.params = params
        self.momentum = momentum
        self.guard = guard
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.ring = SnapshotRing(guard.ring)
        self.step_no = 0
        self.events: List[dict] = []
        self._cooldown = 0
        self._losses: collections.deque = collections.deque(maxlen=16)

    # -- one guarded step -------------------------------------------------
    def step(self, xb, yb) -> dict:
        g = self.guard
        if self.step_no % g.snapshot_every == 0:
            self.ring.push(self.step_no, self.params, self.momentum)
        model = self.model
        if getattr(model, "fault_plan", None) is not None:
            out, taps = model.train_step_faults_metrics(
                self.params, xb, yb, jnp.int32(self.step_no),
                self.momentum)
        else:
            out, taps = model.train_step_metrics(
                self.params, xb, yb, self.momentum)
        if self.momentum is None:
            new_params, loss = out
            new_mom = None
        else:
            new_params, new_mom, loss = out
        loss = float(loss)
        taps = {k: np.asarray(v) for k, v in taps.items()}
        self.registry.merge_numerics_taps(taps,
                                          lanes=_inner(model).lanes())
        alerts = []
        action = None
        if self._cooldown > 0:
            self._cooldown -= 1
        else:
            alerts = detect(taps, loss, g, recent_losses=self._losses,
                            step=self.step_no)
        if alerts:
            self.registry.counter_inc("guard.alerts", len(alerts))
            layer_alerts = [a for a in alerts if a.layer is not None]
            if g.widen and layer_alerts:
                widened = self._widen(layer_alerts[0].layer)
                if widened:
                    action = "widen"
            if g.rollback and len(self.ring):
                snap_step, (p, m, _rng) = self.ring.latest()
                new_params, new_mom = p, m
                self.registry.counter_inc("guard.rollbacks")
                action = f"{action}+rollback" if action else "rollback"
                self.events.append(dict(
                    step=self.step_no, action="rollback",
                    to_step=snap_step,
                    alerts=[dataclasses.asdict(a) for a in alerts]))
            if action:
                self._cooldown = g.cooldown
        else:
            self._losses.append(loss)
        self.params, self.momentum = new_params, new_mom
        self.step_no += 1
        return dict(step=self.step_no - 1, loss=loss, alerts=alerts,
                    action=action)

    # -- recovery: per-layer format widening ------------------------------
    def _widen(self, layer: str) -> bool:
        """Rebuild the model with ``layer`` widened to
        ``guard.widen_fmt``; convert that layer's codes exactly.  Returns
        False (no-op) when the layer is already at least that wide."""
        import dataclasses as _dc

        from ..core.formats import FORMATS
        from ..paper.mlp import PARAM_LAYER, make_mlp
        inner = _inner(self.model)
        old_fmt = inner.fmts[layer]
        new_fmt = FORMATS[self.guard.widen_fmt]
        if old_fmt.qi + old_fmt.qf >= new_fmt.qi + new_fmt.qf:
            return False
        old_plan = inner.plan
        new_plan = old_plan.with_rule(layer, fmt=self.guard.widen_fmt)
        cfg = _dc.replace(self.model.cfg, spec=new_plan)
        self.model = make_mlp("lns", cfg)
        for k, l in PARAM_LAYER.items():
            if l != layer:
                continue
            self.params = dict(self.params)
            self.params[k] = convert_format(self.params[k], old_fmt,
                                            new_fmt)
            if self.momentum is not None:
                self.momentum = dict(self.momentum)
                self.momentum[k] = convert_format(self.momentum[k],
                                                  old_fmt, new_fmt)
        self.registry.counter_inc("guard.widened", layer=layer)
        self.events.append(dict(
            step=self.step_no, action="widen", layer=layer,
            plan_before=str(old_plan), plan_after=str(new_plan)))
        return True

    # -- convenience ------------------------------------------------------
    def run(self, batches) -> List[dict]:
        return [self.step(xb, yb) for xb, yb in batches]


# -- DP device-drop recovery ----------------------------------------------
def recover_segment_partials(inner, params, xb, yb, partials, *,
                             grad_segments: int, lost,
                             reduce_schedule: str = "sequential"):
    """Recompute lost segment partials and recombine canonically.

    ``partials`` is a per-parameter stack of per-segment gradient codes
    (leading segment axis, as ``per_segment_grads`` emits) in which the
    slots named by ``lost`` are unavailable — a dropped device, a lost
    all-gather message (their current contents are ignored).  Because the
    canonical segmentation makes slot ``s`` a pure function of segment
    ``s``'s batch rows, each lost slot is recomputed from exactly those
    rows (``per_segment_grads(rows_s, 1)``), spliced in, and the full
    stack folded on the fixed schedule — so the combined gradients (and
    any update applied to them) are **bit-identical** to a fresh run at
    the surviving device count: device count never changed which
    arithmetic combines a segment, only where it was computed.

    Returns ``{param: combined grad}`` (pass to ``apply_updates``).
    """
    from ..distributed.lns_reduce import combine_partials
    b = xb.shape[0]
    if b % grad_segments:
        raise ValueError(
            f"batch {b} not divisible into {grad_segments} segments")
    seg = b // grad_segments
    lost = sorted(set(int(s) for s in lost))
    for s in lost:
        if not (0 <= s < grad_segments):
            raise ValueError(
                f"lost segment {s} out of range [0, {grad_segments})")
    repaired = {k: g for k, g in partials.items()}
    for s in lost:
        sl = slice(s * seg, (s + 1) * seg)
        g1, _ = inner.per_segment_grads(params, xb[sl], yb[sl], 1)
        for k in repaired:
            g = repaired[k]
            code = g.code.at[s].set(g1[k].code[0])
            sign = g.sign.at[s].set(g1[k].sign[0])
            repaired[k] = type(g)(code, sign)
    return {k: combine_partials(g, inner.param_engines[k],
                                schedule=reduce_schedule)
            for k, g in repaired.items()}


def shrink(model, surviving: int):
    """Rebuild a DP model on ``surviving`` devices (post device drop).

    The canonical segmentation is fixed by the plan's
    ``reduce.grad_segments``, so the shrunk model trains bit-identically
    to the pre-drop model (``surviving`` must divide ``grad_segments``).
    """
    import dataclasses as _dc

    from ..distributed.lns_dp import LNSDataParallelMLP
    if not isinstance(model, LNSDataParallelMLP):
        raise TypeError("shrink() applies to LNSDataParallelMLP models")
    dp = _dc.replace(model.dp, num_devices=surviving)
    return LNSDataParallelMLP(model.cfg, dp)
